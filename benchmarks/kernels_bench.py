"""Kernel + engine microbenchmarks: Pallas (interpret) vs jnp oracle
correctness-at-scale, and the jitted batched engine's QPS vs the numpy
reference engine."""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import query_ref as qr
from repro.core.engine import SearchParams, device_put_index, make_search_fn
from repro.core.khi import KHIConfig, KHIIndex
from repro.data import make_dataset, make_queries
from repro.kernels import ops
from repro.kernels.ref import l2dist_qn_ref

from .common import SCALES, save_results, scaled_spec


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(scale: str = "small"):
    s = SCALES[scale]
    rng = np.random.default_rng(0)
    out = {}

    # kernel: all-pairs distance (the Prefiltering/bulk-build hot spot)
    B, N, D = 8, 4096, 128
    q = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((N, D)), jnp.float32)
    t_ref = _time(jax.jit(l2dist_qn_ref), q, c)
    t_pal = _time(lambda a, b: ops.l2dist(a, b, interpret=True), q, c)
    err = float(jnp.max(jnp.abs(ops.l2dist(q, c, interpret=True)
                                - l2dist_qn_ref(q, c))))
    out["l2dist_qn"] = dict(shape=[B, N, D], ref_us=t_ref * 1e6,
                            pallas_interpret_us=t_pal * 1e6, max_err=err)
    print(f"[kernels] l2dist_qn ref {t_ref*1e6:.0f}us, interpret "
          f"{t_pal*1e6:.0f}us (CPU interpret overhead expected), err {err:.1e}",
          flush=True)

    # engine: jitted batched search vs numpy reference
    spec = scaled_spec("laion", scale)
    vecs, attrs = make_dataset(spec)
    idx = KHIIndex.build(vecs, attrs, KHIConfig(M=s["M"], builder="bulk"))
    Q, preds = make_queries(vecs, attrs, n_queries=64, sigma=1 / 16, seed=3)
    di = device_put_index(idx)
    params = SearchParams(k=10, ef=64, c_e=10, c_n=s["M"])
    fn = make_search_fn(params, di=di, on_undersized="adjust")
    qlo = jnp.asarray(np.stack([p.lo for p in preds]))
    qhi = jnp.asarray(np.stack([p.hi for p in preds]))
    qv = jnp.asarray(Q)
    t_jit = _time(fn, di, qv, qlo, qhi)
    t0 = time.perf_counter()
    for q_, p_ in zip(Q, preds):
        qr.query(idx, q_, p_, 10, ef=64)
    t_np = time.perf_counter() - t0
    out["engine"] = dict(batch=64, jit_batch_ms=t_jit * 1e3,
                         jit_qps=64 / t_jit, numpy_qps=64 / t_np)
    print(f"[kernels] engine jit {64/t_jit:.0f} QPS vs numpy ref "
          f"{64/t_np:.0f} QPS (CPU)", flush=True)
    save_results("kernels", out)
    return out


def csv_lines(out):
    k = out["l2dist_qn"]
    return [
        f"kernel_l2dist_qn,{k['pallas_interpret_us']:.0f},"
        f"ref_us={k['ref_us']:.0f};max_err={k['max_err']:.1e}",
        f"engine_jit_batch64,{out['engine']['jit_batch_ms'] * 1e3:.0f},"
        f"jit_qps={out['engine']['jit_qps']:.0f}"
        f";numpy_qps={out['engine']['numpy_qps']:.0f}",
    ]
