"""Build-time benchmark: seconds × builder × n, with a parity assert.

Measures KHI construction through each builder — ``incremental`` (paper
Alg. 5, smallest n only: it is the Python-loop path the device builder
exists to replace), ``bulk`` (numpy exact top-ef_b + per-row RNG prune)
and ``device`` (the jitted array program, ``core/build_device.py``) — on
the same dataset at a sweep of corpus sizes. The device builder is
measured twice: cold (first build at that shape — includes every jit
trace) and warm (rebuild with traces cached — the steady state of
sharded/epoch rebuilds, where all shards share one trace set).

Hard assert at every point: the device ``nbrs`` planes are bit-identical
to the numpy bulk builder's (the tier-1 parity contract, at benchmark
scale). The headline derived metric is ``device_speedup`` =
bulk_seconds / device_warm_seconds at each n.

    PYTHONPATH=src python -m benchmarks.build_bench --scale smoke
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.build_device import build_graphs_device
from repro.core.khi import KHIConfig, KHIIndex
from repro.data import make_dataset

from .common import SCALES, save_results, scaled_spec

BUILD_SIZES = {
    "smoke": (600, 1500, 3000),
    "small": (1500, 4000, 8000),
    "paper": (5000, 10000, 20000),
}


def run(scale: str = "smoke", dataset: str = "laion"):
    s = SCALES[scale]
    M = s["M"]
    rows = []
    for n in BUILD_SIZES[scale]:
        spec = dataclasses.replace(scaled_spec(dataset, scale), n=n)
        vecs, attrs = make_dataset(spec)

        row = dict(dataset=dataset, n=n, d=spec.d, M=M)
        if n == BUILD_SIZES[scale][0]:
            inc = KHIIndex.build(vecs, attrs,
                                 KHIConfig(M=M, builder="incremental"))
            row["incremental_s"] = inc.build_seconds

        bulk = KHIIndex.build(vecs, attrs, KHIConfig(M=M, builder="bulk"))
        row["bulk_s"] = bulk.build_seconds

        dev_cold = KHIIndex.build(vecs, attrs,
                                  KHIConfig(M=M, builder="device"))
        row["device_cold_s"] = dev_cold.build_seconds
        t0 = time.perf_counter()
        warm_nbrs = build_graphs_device(dev_cold.tree, vecs, M=M)
        row["device_warm_s"] = time.perf_counter() - t0

        # parity contract at benchmark scale
        assert (dev_cold.nbrs == bulk.nbrs).all(), \
            f"device/bulk parity broke at n={n}"
        assert (warm_nbrs == bulk.nbrs).all()

        row["device_speedup"] = row["bulk_s"] / row["device_warm_s"]
        row["device_speedup_cold"] = row["bulk_s"] / row["device_cold_s"]
        rows.append(row)
        print(f"[build_bench] n={n}: bulk {row['bulk_s']:.2f}s, device "
              f"{row['device_cold_s']:.2f}s cold / "
              f"{row['device_warm_s']:.2f}s warm "
              f"(x{row['device_speedup']:.1f} warm, "
              f"x{row['device_speedup_cold']:.1f} cold)", flush=True)
    payload = {"rows": rows,
               "config": {"scale": scale, "dataset": dataset, "M": M,
                          "parity": "device nbrs == bulk nbrs (asserted)"}}
    save_results("build", payload)
    return payload


def csv_lines(payload) -> list:
    out = []
    for r in payload["rows"]:
        out.append(f"build_bulk_n{r['n']},{r['bulk_s'] * 1e6:.0f},")
        out.append(f"build_device_n{r['n']},{r['device_warm_s'] * 1e6:.0f},"
                   f"speedup_vs_bulk={r['device_speedup']:.2f}"
                   f";cold={r['device_cold_s']:.2f}s")
        if "incremental_s" in r:
            out.append(f"build_incremental_n{r['n']},"
                       f"{r['incremental_s'] * 1e6:.0f},")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="smoke",
                    choices=list(BUILD_SIZES))
    ap.add_argument("--dataset", default="laion")
    args = ap.parse_args()
    print("\n".join(csv_lines(run(args.scale, args.dataset))))
