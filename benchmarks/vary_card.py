"""Paper Fig. 7: QPS at matched recall while predicate cardinality |B|
varies (dblp, m=4). Gains grow with cardinality."""

from __future__ import annotations

from repro.data import make_dataset, make_queries

from .common import (SCALES, build_methods, qps_at_recall, run_queries,
                     save_results, scaled_spec)


def run(scale: str = "small", dataset: str = "dblp", sigma: float = 1 / 64,
        k: int = 10):
    s = SCALES[scale]
    spec = scaled_spec(dataset, scale)
    vecs, attrs = make_dataset(spec)
    methods = build_methods(vecs, attrs, M=s["M"])
    rows = []
    for card in range(2, spec.m + 1):
        Q, preds = make_queries(vecs, attrs, n_queries=s["n_queries"],
                                sigma=sigma, cardinality=card, seed=17)
        pts = {m: [run_queries(m, methods[m], vecs, attrs, Q, preds, k, ef)
                   for ef in (s["efs"] if m != "prefilter" else (0,))]
               for m in methods}
        qk = qps_at_recall(pts["khi"], s["target"])
        qi = qps_at_recall(pts["irange"], s["target"])
        rows.append(dict(cardinality=card, khi_qps=qk, irange_qps=qi,
                         prefilter_qps=pts["prefilter"][0]["qps"],
                         speedup=(qk / qi) if qk and qi else None))
        print(f"[vary_card] |B|={card}: khi={qk and round(qk)} "
              f"irg={qi and round(qi)} "
              f"x{rows[-1]['speedup'] and round(rows[-1]['speedup'], 2)}",
              flush=True)
    save_results("vary_card", rows)
    return rows


def csv_lines(rows):
    return [f"fig7_card{r['cardinality']},"
            f"{1e6 / r['khi_qps'] if r['khi_qps'] else 0:.1f},"
            f"x_irange={r['speedup'] or 0:.2f}" for r in rows]
