"""Canonical QPS smoke trajectory for the wide-frontier engine (CI-run).

Runs the batched device engine over one small fixed-seed workload at
E in {1, 4} x a short ef grid, writes ``experiments/bench_qps.json``
(the committed perf trajectory), and **asserts inline**:

  * E=1/E=4 top-k id parity — the wide frontier reorders hops, it must not
    change what is found (mean per-query overlap >= PARITY_FLOOR);
  * recall(E=4) >= recall(E=1) - RECALL_SLACK at every ef;
  * hops(E=4) < hops(E=1) at every ef (fewer, fatter hops).

Those three are deterministic and gate CI. The wall-clock claim — E=4
beating E=1 QPS at equal-or-better recall on at least one ef — is
*recorded* in the summary (the committed file shows it) but only enforced
with ``strict_qps=True``: a relative timing assert on a shared CI runner
would race the scheduler, not test the code.

On CPU the Pallas backends run in interpret mode; the committed file is
produced with backend="jnp" (the portable path) so the numbers track the
engine's shape, not the interpreter's overhead.

    PYTHONPATH=src python -m benchmarks.qps_smoke
"""

from __future__ import annotations

import numpy as np

from repro.data import make_dataset, make_queries

from .common import (SCALES, build_methods, engine_search, ground_truth,
                     recall_at_k, save_results, scaled_spec)

DATASET = "laion"
SIGMAS = {"1/16": 1 / 16, "1/64": 1 / 64}
EFS = (32, 64, 128)
EXPAND = (1, 4)
E_LO, E_HI = min(EXPAND), max(EXPAND)   # the compared pair
BACKEND = "jnp"
PARITY_FLOOR = 0.90    # mean E1-vs-E4 top-k overlap
RECALL_SLACK = 0.02
REPEATS = 2            # keep the better wall-clock of N runs per point


def _parity(ids_a: np.ndarray, ids_b: np.ndarray) -> float:
    """Mean per-query overlap of the returned id sets (denominator is the
    larger set so padding asymmetry can't inflate it)."""
    ov = []
    for a, b in zip(ids_a, ids_b):
        sa = set(int(x) for x in a if x >= 0)
        sb = set(int(x) for x in b if x >= 0)
        if not sa and not sb:
            continue
        ov.append(len(sa & sb) / max(len(sa), len(sb), 1))
    return float(np.mean(ov)) if ov else 1.0


def run(scale: str = "smoke", k: int = 10, strict_qps: bool = False):
    s = SCALES[scale]
    spec = scaled_spec(DATASET, scale)
    vecs, attrs = make_dataset(spec)
    index = build_methods(vecs, attrs, M=s["M"], which=("khi",))["khi"]
    rows = []
    checks = {"parity": [], "recall": [], "hops": [], "qps_wins": 0}
    for sname, sigma in SIGMAS.items():
        Q, preds = make_queries(vecs, attrs, n_queries=s["n_queries"],
                                sigma=sigma, seed=11)
        gt = ground_truth(vecs, attrs, Q, preds, k)   # once per workload
        for ef in EFS:
            pts = {}
            for E in EXPAND:
                ids, hops, dt = engine_search(index, Q, preds, k, ef,
                                              backend=BACKEND,
                                              expand_width=E,
                                              repeats=REPEATS)
                pts[E] = {
                    "method": f"engine[{BACKEND},E{E}]", "ef": ef, "k": k,
                    "expand_width": E, "dataset": DATASET, "sigma": sname,
                    "scale": scale,
                    "recall": recall_at_k(vecs, attrs, Q, preds, ids, k,
                                          gt=gt),
                    "qps": len(Q) / dt, "hops": float(hops.mean()),
                    "_ids": ids,
                }
            par = _parity(pts[E_LO].pop("_ids"), pts[E_HI].pop("_ids"))
            rows.extend(pts.values())
            checks["parity"].append(par)
            checks["recall"].append(pts[E_HI]["recall"] - pts[E_LO]["recall"])
            checks["hops"].append((pts[E_HI]["hops"], pts[E_LO]["hops"]))
            if (pts[E_HI]["qps"] > pts[E_LO]["qps"]
                    and pts[E_HI]["recall"] >= pts[E_LO]["recall"] - 1e-9):
                checks["qps_wins"] += 1
            print(f"[qps_smoke] sigma={sname:5s} ef={ef:4d} "
                  f"E{E_LO}: r={pts[E_LO]['recall']:.3f} "
                  f"q={pts[E_LO]['qps']:7.1f} "
                  f"h={pts[E_LO]['hops']:6.1f} | "
                  f"E{E_HI}: r={pts[E_HI]['recall']:.3f} "
                  f"q={pts[E_HI]['qps']:7.1f} "
                  f"h={pts[E_HI]['hops']:6.1f} | parity={par:.3f}",
                  flush=True)

    # ---- inline assertions (deterministic; CI gates on these)
    mean_par = float(np.mean(checks["parity"]))
    assert mean_par >= PARITY_FLOOR, (
        f"E=1/E=4 top-k id parity {mean_par:.3f} < {PARITY_FLOOR}")
    assert all(d >= -RECALL_SLACK for d in checks["recall"]), (
        f"E=4 lost recall beyond slack: {checks['recall']}")
    assert all(h4 < h1 for h4, h1 in checks["hops"]), (
        f"E=4 did not reduce hops everywhere: {checks['hops']}")
    # ---- wall-clock claim: recorded always, enforced only on request
    if checks["qps_wins"] < 1:
        msg = "E=4 never beat E=1 QPS at equal-or-better recall this run"
        if strict_qps:
            raise AssertionError(msg)
        print(f"[qps_smoke] WARNING: {msg} (timing noise is expected on "
              f"shared runners; the committed trajectory records the win)",
              flush=True)
    summary = {
        "dataset": DATASET, "scale": scale, "backend": BACKEND,
        "parity_mean": mean_par,
        "qps_wins_at_equal_or_better_recall": checks["qps_wins"],
        "hop_ratio_mean": float(np.mean([h4 / h1
                                         for h4, h1 in checks["hops"]])),
    }
    payload = {"summary": summary, "rows": rows}
    save_results("qps", payload)
    print(f"[qps_smoke] OK parity={mean_par:.3f} "
          f"hop_ratio={summary['hop_ratio_mean']:.2f} "
          f"qps_wins={checks['qps_wins']}/{len(EFS) * len(SIGMAS)}",
          flush=True)
    return payload


def csv_lines(payload):
    out = []
    for r in payload["rows"]:
        qps = r["qps"] or 0.0
        us = 1e6 / qps if qps else 0.0
        out.append(
            f"qps_smoke_{r['dataset']}_{r['sigma'].replace('/', '_')}"
            f"_ef{r['ef']}_E{r['expand_width']},{us:.1f},"
            f"recall={r['recall']:.3f};hops={r['hops']:.1f}")
    return out


if __name__ == "__main__":
    run()
